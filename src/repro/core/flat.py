"""Flat-buffer parameter representation for the compiled execution path.

The pytree aggregation rules in ``repro.core.aggregation`` walk the model
tree on every round — fine for exploration, but the hot path wants a single
contiguous vector: client deltas/grads then stack into dense ``(K, D)``
buffers that feed the fused Pallas FOLB kernel directly, and whole-run
``lax.scan`` engines can carry one array instead of a tree.

``FlatSpec`` is the *static* unravel recipe (leaf shapes/dtypes + treedef +
padding + buffer dtype), hashable so it can ride through ``jax.jit`` as a
static argument.  ``D_pad`` rounds the parameter count up to the Pallas
streaming tile (``kernels.folb_aggregate.TILE_D``, or a multiple of it when
the buffer is sharded over a device mesh); the padding lanes are zero and
stay zero through every aggregation rule (zero delta, zero grad), so
``unravel(spec, ravel(spec, tree))`` is exact — bit-for-bit — for fp32
trees under the default fp32 buffer dtype and value-preserving (one fp32
round-trip) otherwise.

Buffer dtype (``buf_dtype``): parameters must survive the scan-carry
round-trip exactly, so they stay fp32.  Gradient/delta buffers only feed
the FOLB kernels — which upcast tile-by-tile and accumulate in fp32 VMEM —
so they can be stored in bf16, halving the ``(K, D)`` HBM traffic that is
nearly all of FOLB's server-side cost at transformer scale.  A bf16 buffer
holds round-to-nearest-even bf16 values: the ravel→unravel round-trip of an
fp32 tree is then one bf16 rounding per element (relative error ≤ 2^-9 +
subnormal underflow below ~1e-38; see tests/test_flat.py for the bound).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.folb_aggregate import TILE_D


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static recipe for flattening/unflattening one model pytree.

    Hashable (treedef, shape/dtype tuples and the buffer dtype are
    hashable), so functions taking a FlatSpec can mark it static under jit.
    """
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    pad_to: int = TILE_D
    buf_dtype: Any = jnp.dtype(jnp.float32)

    @property
    def sizes(self) -> Tuple[int, ...]:
        out = []
        for s in self.shapes:
            n = 1
            for d in s:
                n *= d
            out.append(n)
        return tuple(out)

    @property
    def D(self) -> int:
        """Unpadded parameter count."""
        return sum(self.sizes)

    @property
    def D_pad(self) -> int:
        """Parameter count rounded up to the kernel streaming tile."""
        return self.D + (-self.D) % self.pad_to


def spec_of(tree, pad_to: int = TILE_D, buf_dtype=jnp.float32) -> FlatSpec:
    """Build the static FlatSpec for a parameter pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return FlatSpec(treedef=treedef,
                    shapes=tuple(tuple(x.shape) for x in leaves),
                    dtypes=tuple(jnp.asarray(x).dtype for x in leaves),
                    pad_to=pad_to,
                    buf_dtype=jnp.dtype(buf_dtype))


def with_buf_dtype(spec: FlatSpec, buf_dtype) -> FlatSpec:
    """The same unravel recipe targeting a different buffer dtype (e.g. the
    bf16 grad/delta variant of an fp32 parameter spec)."""
    return dataclasses.replace(spec, buf_dtype=jnp.dtype(buf_dtype))


def ravel(spec: FlatSpec, tree) -> jnp.ndarray:
    """Pytree -> (D_pad,) buf_dtype vector (zero-padded past D)."""
    leaves = jax.tree_util.tree_leaves(tree)
    flat = jnp.concatenate(
        [jnp.asarray(x).reshape(-1).astype(spec.buf_dtype) for x in leaves])
    pad = spec.D_pad - spec.D
    return jnp.pad(flat, (0, pad)) if pad else flat


def ravel_stacked(spec: FlatSpec, stacked) -> jnp.ndarray:
    """Pytree with leading client axis K -> (K, D_pad) buf_dtype buffer."""
    leaves = jax.tree_util.tree_leaves(stacked)
    K = leaves[0].shape[0]
    flat = jnp.concatenate(
        [jnp.asarray(x).reshape(K, -1).astype(spec.buf_dtype)
         for x in leaves], axis=1)
    pad = spec.D_pad - spec.D
    return jnp.pad(flat, ((0, 0), (0, pad))) if pad else flat


def unravel(spec: FlatSpec, flat: jnp.ndarray):
    """(D_pad,) or (D,) vector -> pytree with the spec's shapes/dtypes."""
    leaves = []
    off = 0
    for shape, dtype, n in zip(spec.shapes, spec.dtypes, spec.sizes):
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def unravel_stacked(spec: FlatSpec, flat: jnp.ndarray):
    """(K, D_pad) buffer -> pytree with a leading K axis per leaf."""
    K = flat.shape[0]
    leaves = []
    off = 0
    for shape, dtype, n in zip(spec.shapes, spec.dtypes, spec.sizes):
        leaves.append(
            flat[:, off:off + n].reshape((K,) + shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)

"""Device-selection distributions (paper Sec. III).

All distributions return a length-N probability vector P^t; sampling draws
a size-K **multiset with replacement** (footnote 1 of the paper: K repeated
categorical trials).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_probs(n: int) -> jnp.ndarray:
    return jnp.full((n,), 1.0 / n)


_TINY = 1e-20   # below this the scores carry no signal -> uniform fallback


def lb_near_optimal_probs(inner_products: jnp.ndarray) -> jnp.ndarray:
    """Definition 1: P_k ∝ |<grad f, grad F_k>| given the N inner products."""
    a = jnp.abs(inner_products)
    s = jnp.sum(a)
    n = a.shape[0]
    return jnp.where(s > _TINY, a / jnp.where(s > _TINY, s, 1.0),
                     jnp.full((n,), 1.0 / n))


def norm_estimate_probs(grad_norms: jnp.ndarray) -> jnp.ndarray:
    """Sec. III-D2 (Cauchy-Schwarz sub-optimal estimate): P_k ∝ ||grad F_k||."""
    s = jnp.sum(grad_norms)
    n = grad_norms.shape[0]
    return jnp.where(s > _TINY, grad_norms / jnp.where(s > _TINY, s, 1.0),
                     jnp.full((n,), 1.0 / n))


def het_aware_scores(inner_products: jnp.ndarray, gammas: jnp.ndarray,
                     psi: float, global_grad_sqnorm: jnp.ndarray) -> jnp.ndarray:
    """Sec. V: I_k = <grad f, grad F_k> - psi * gamma_k * ||grad f||^2."""
    return inner_products - psi * gammas * global_grad_sqnorm


def het_aware_probs(inner_products, gammas, psi, global_grad_sqnorm):
    """P_lbh (Sec. V): P_k ∝ |I_k|."""
    return lb_near_optimal_probs(
        het_aware_scores(inner_products, gammas, psi, global_grad_sqnorm))


def deadline_feasible_weights(expected_latency: jnp.ndarray, deadline: float,
                              softness: float = 0.0) -> jnp.ndarray:
    """Smooth probability-of-making-the-deadline proxy per device.

    σ((deadline − ℓ_k) / s): ≈1 for devices whose expected round latency
    ℓ_k is comfortably inside the deadline, ≈0 for hopeless stragglers.
    The sigmoid (rather than a hard cut) keeps borderline devices sampleable
    — their realized latency is stochastic in the local-step draw.
    An infinite deadline weights every device 1.
    """
    lat = jnp.asarray(expected_latency, jnp.float32)
    if not jnp.isfinite(deadline):
        return jnp.ones_like(lat)
    s = softness if softness > 0.0 else max(float(deadline), 1e-9) / 8.0
    return jax.nn.sigmoid((deadline - lat) / s)


def latency_aware_probs(scores: jnp.ndarray, expected_latency: jnp.ndarray,
                        deadline: float, softness: float = 0.0) -> jnp.ndarray:
    """Deadline/latency-aware selection: P_k ∝ |I_k| · σ((D − ℓ_k)/s).

    `scores` are the learning-utility scores (inner products, or the Sec. V
    heterogeneity-aware I_k; pass ones for pure latency-aware sampling);
    the feasibility weight turns the ψγ-style penalty idea into an actual
    scheduling signal.  Falls back to uniform when everything is hopeless
    (all weighted scores ~ 0), via the same guard as Definition 1.
    """
    w = jnp.abs(jnp.asarray(scores, jnp.float32)) * deadline_feasible_weights(
        expected_latency, deadline, softness)
    return lb_near_optimal_probs(w)


def sample_multiset(key, probs: jnp.ndarray, k: int) -> jnp.ndarray:
    """K categorical draws with replacement -> (K,) int32 client ids."""
    return jax.random.categorical(
        key, jnp.log(jnp.maximum(probs, 1e-30)), shape=(k,)).astype(jnp.int32)


def sample_uniform_ids(key, n: int, k: int) -> jnp.ndarray:
    """K uniform-with-replacement draws -> (K,) int32 client ids.

    Same distribution as ``sample_multiset(key, uniform_probs(n), k)`` but
    O(K) work and no (N,) probability vector, so selection cost is
    independent of fleet size — the ``sampler="indexed"`` path that makes
    million-device populations viable.  (Different bits from the
    categorical sampler for the same key: the two are separate,
    self-consistent timelines.)
    """
    return jax.random.randint(key, (k,), 0, n, dtype=jnp.int32)

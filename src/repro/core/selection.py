"""Device-selection distributions (paper Sec. III).

All distributions return a length-N probability vector P^t; sampling draws
a size-K **multiset with replacement** (footnote 1 of the paper: K repeated
categorical trials).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_probs(n: int) -> jnp.ndarray:
    return jnp.full((n,), 1.0 / n)


_TINY = 1e-20   # below this the scores carry no signal -> uniform fallback


def lb_near_optimal_probs(inner_products: jnp.ndarray) -> jnp.ndarray:
    """Definition 1: P_k ∝ |<grad f, grad F_k>| given the N inner products."""
    a = jnp.abs(inner_products)
    s = jnp.sum(a)
    n = a.shape[0]
    return jnp.where(s > _TINY, a / jnp.where(s > _TINY, s, 1.0),
                     jnp.full((n,), 1.0 / n))


def norm_estimate_probs(grad_norms: jnp.ndarray) -> jnp.ndarray:
    """Sec. III-D2 (Cauchy-Schwarz sub-optimal estimate): P_k ∝ ||grad F_k||."""
    s = jnp.sum(grad_norms)
    n = grad_norms.shape[0]
    return jnp.where(s > _TINY, grad_norms / jnp.where(s > _TINY, s, 1.0),
                     jnp.full((n,), 1.0 / n))


def het_aware_scores(inner_products: jnp.ndarray, gammas: jnp.ndarray,
                     psi: float, global_grad_sqnorm: jnp.ndarray) -> jnp.ndarray:
    """Sec. V: I_k = <grad f, grad F_k> - psi * gamma_k * ||grad f||^2."""
    return inner_products - psi * gammas * global_grad_sqnorm


def het_aware_probs(inner_products, gammas, psi, global_grad_sqnorm):
    """P_lbh (Sec. V): P_k ∝ |I_k|."""
    return lb_near_optimal_probs(
        het_aware_scores(inner_products, gammas, psi, global_grad_sqnorm))


def sample_multiset(key, probs: jnp.ndarray, k: int) -> jnp.ndarray:
    """K categorical draws with replacement -> (K,) int32 client ids."""
    return jax.random.categorical(
        key, jnp.log(jnp.maximum(probs, 1e-30)), shape=(k,)).astype(jnp.int32)

"""Theoretical loss-decrease bounds (Theorem 1, Proposition 1, Definition 1,
Proposition 2, Theorem 3) — used for diagnostics and for the property tests
that check the bounds actually hold on strongly-convex problems.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Assumption constants: L-Lipschitz gradients, B-dissimilarity,
    sigma-bounded Hessians, gamma-inexact solvers, prox weight mu."""
    L: float
    B: float
    sigma: float
    gamma: float
    mu: float

    @property
    def mu_prime(self) -> float:
        return self.mu - self.sigma


def penalty_term(c: ProblemConstants) -> float:
    """B(L(γ+1)/(μμ′) + γ/μ + BL(1+γ)²/(2μ′²)) — shared by Thm 1 / Prop 1 /
    Def 1 / Prop 2."""
    mu, mup, g = c.mu, c.mu_prime, c.gamma
    return c.B * (c.L * (g + 1) / (mu * mup) + g / mu
                  + c.B * c.L * (1 + g) ** 2 / (2 * mup ** 2))


def theorem1_bound(f_t, expected_inner_sum, grad_sqnorm, K, c: ProblemConstants):
    """E[f(w^{t+1})] <= f(w^t) - E[sum_{k in S_t} <∇f,∇F_k>]/(Kμ) + pen·||∇f||²."""
    return f_t - expected_inner_sum / (K * c.mu) + penalty_term(c) * grad_sqnorm


def proposition1_bound(f_t, expected_abs_inner_sum, grad_sqnorm, K,
                       c: ProblemConstants):
    """Prop. 1 (signed aggregation): inner products replaced by |·|."""
    return (f_t - expected_abs_inner_sum / (K * c.mu)
            + penalty_term(c) * grad_sqnorm)


def def1_bound(f_t, inner_products, grad_sqnorm, c: ProblemConstants):
    """Definition 1: LB-near-optimal selection,
    E-term = sum_k |<∇f,∇F_k>| P_lb_k = sum_k <·>² / sum_k' |<·>|."""
    a = jnp.abs(inner_products)
    e_term = jnp.sum(a ** 2) / jnp.maximum(jnp.sum(a), 1e-30)
    return f_t - e_term / c.mu + penalty_term(c) * grad_sqnorm


def proposition2_bound(f_t, inner_products, grad_sqnorm, K, N,
                       c: ProblemConstants):
    """Prop. 2 (single-set FOLB): E-term = (K/N) sum_k |<∇f,∇F_k>| / μ."""
    e_term = (K / N) * jnp.sum(jnp.abs(inner_products))
    return f_t - e_term / c.mu + penalty_term(c) * grad_sqnorm


def theorem3_psi(K: int, c: ProblemConstants) -> float:
    """ψ = B(L/(μμ′) + 1/μ + 3LB/(2Kμ′²)) — the heterogeneity penalty weight
    that Sec. V-B folds into a single line-searched hyper-parameter."""
    mu, mup = c.mu, c.mu_prime
    return c.B * (c.L / (mu * mup) + 1 / mu + 3 * c.L * c.B / (2 * K * mup ** 2))


def theorem3_bound(f_t, expected_score_sum, grad_sqnorm, K,
                   c: ProblemConstants):
    """Thm. 3: E-term uses I_k = <∇f,∇F_k> − ψ γ_k ||∇f||²; extra additive
    penalty (LB²/(2μ′²) + LB/(μμ′))||∇f||²."""
    mu, mup = c.mu, c.mu_prime
    pen = (c.L * c.B ** 2 / (2 * mup ** 2) + c.L * c.B / (mu * mup))
    return f_t - expected_score_sum / (K * mu) + pen * grad_sqnorm

"""Hyper-parameter line search (paper Sec. V-B / VI-A): exponential grids
for μ and ψ, selected by best end-of-budget metric on short runs — plus
the cross-product grid builder the compiled sweep engine
(``repro.fed.sweep_engine``) consumes."""
from __future__ import annotations

import itertools
from typing import Callable, Dict, Sequence, Tuple

import jax.numpy as jnp

MU_GRID: Sequence[float] = (1e-4, 1e-3, 1e-2, 1e-1, 1.0)
PSI_GRID: Sequence[float] = (1e-1, 1.0, 10.0, 100.0)


def hypers_of(cfg, fields: Sequence[str]) -> Dict[str, jnp.ndarray]:
    """Extract the named sweepable hyper-parameters from a config as f32
    scalars — the traced-operand dict every engine passes into its jitted
    round step (one shared helper so the sync and async engines cannot
    drift on dtype or ordering)."""
    return {name: jnp.float32(getattr(cfg, name)) for name in fields}


def sweep_grid(**axes: Sequence[float]) -> Tuple[Dict[str, float], ...]:
    """Cross product of named hyper-parameter axes -> one override dict
    per grid point, in deterministic row-major order (the LAST named axis
    varies fastest, like ``itertools.product``).

        sweep_grid(lr=(0.01, 0.1), mu=(0.0, 1.0))
        -> ({'lr': 0.01, 'mu': 0.0}, {'lr': 0.01, 'mu': 1.0},
            {'lr': 0.1, 'mu': 0.0},  {'lr': 0.1, 'mu': 1.0})

    Axis names are not validated here — ``sweep_engine.SweepSpec`` checks
    them against the engine's sweepable field set.
    """
    if not axes:
        return ({},)
    # materialize each axis exactly once: a one-shot iterator must not be
    # consumed by validation and then re-read empty by the product
    materialized = {name: tuple(vals) for name, vals in axes.items()}
    for name, vals in materialized.items():
        if not vals:
            raise ValueError(f"sweep axis {name!r} is empty")
    names = tuple(materialized.keys())
    return tuple(
        {n: float(v) for n, v in zip(names, combo)}
        for combo in itertools.product(*materialized.values()))


def line_search(run_fn: Callable[[float], float],
                grid: Sequence[float],
                maximize: bool = True) -> Tuple[float, Dict[float, float]]:
    """Evaluate run_fn over an exponential grid; return (best_value, scores).

    run_fn maps a hyper-parameter value to a scalar figure of merit (e.g.
    final test accuracy of a short federated run)."""
    scores = {v: float(run_fn(v)) for v in grid}
    pick = max if maximize else min
    best = pick(scores, key=scores.get)
    return best, scores


def joint_search(run_fn: Callable[[float, float], float],
                 mu_grid: Sequence[float] = MU_GRID,
                 psi_grid: Sequence[float] = PSI_GRID,
                 maximize: bool = True):
    """Two-stage search: tune μ with ψ = 0, then ψ at the chosen μ —
    the procedure the paper describes for FOLB-het."""
    mu_best, mu_scores = line_search(lambda m: run_fn(m, 0.0), mu_grid,
                                     maximize)
    psi_best, psi_scores = line_search(lambda p: run_fn(mu_best, p),
                                       psi_grid, maximize)
    return (mu_best, psi_best), {"mu": mu_scores, "psi": psi_scores}

"""Pytree linear algebra used throughout the FL core.

All reductions are performed in fp32 regardless of leaf dtype (aggregation
weights are scalars; precision there is cheap and matters).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_dot(a, b) -> jnp.ndarray:
    """<a, b> over all leaves, fp32 accumulate.

    NOTE: implemented as multiply+sum (not vdot) deliberately — vdot
    flattens its operands, and GSPMD cannot reshape a sharded array to 1-D
    without replicating it first (measured: 10 GiB/device of gathered
    parameter copies on a 256-chip mesh).  Elementwise multiply keeps the
    operands' sharding and the reduction lowers to a local sum + scalar
    all-reduce."""
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.zeros((), jnp.float32))


def tree_sqnorm(a) -> jnp.ndarray:
    return tree_dot(a, a)


def tree_norm(a) -> jnp.ndarray:
    return jnp.sqrt(tree_sqnorm(a))


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), a)


def tree_axpy(s, x, y):
    """y + s * x, computed leafwise in fp32, cast back to y's dtypes."""
    return jax.tree.map(
        lambda xl, yl: (yl.astype(jnp.float32)
                        + s * xl.astype(jnp.float32)).astype(yl.dtype), x, y)


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_size(a) -> int:
    return sum(x.size for x in jax.tree.leaves(a))


def tree_stack(trees):
    """Stack a list of identically-structured trees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_index(tree, i):
    return jax.tree.map(lambda x: x[i], tree)

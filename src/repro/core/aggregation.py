"""Aggregation rules (paper Eq. 2, Eq. 5, IV-A, IV-C, V-B).

Inputs use the *stacked-client* convention: `deltas` and `grads` are pytrees
whose leaves carry a leading K axis (client index within the sampled
multiset).  All rules return the new global parameters.

These are the reference (pure-jnp) implementations; ``repro.kernels``
provides a fused Pallas kernel for the single-set FOLB rule that performs
the K inner products and the weighted delta reduction in one HBM pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tree


def _stacked_dot(stacked, single) -> jnp.ndarray:
    """<stacked_k, single> for each k -> (K,) fp32."""
    return jax.vmap(lambda t: tree.tree_dot(t, single))(stacked)


def _weighted_sum(stacked, weights):
    """sum_k weights[k] * stacked[k], leafwise fp32."""
    def leaf(x):
        w = weights.reshape(weights.shape + (1,) * (x.ndim - 1))
        return jnp.sum(x.astype(jnp.float32) * w, axis=0)
    return jax.tree.map(leaf, stacked)


def mean_of(stacked):
    """grad-f estimate: (1/K) sum_k stacked[k]  (Eq. IV-A nabla_i f)."""
    return jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0),
                        stacked)


def fedavg_aggregate(w_t, deltas):
    """Eq. 2: w^{t+1} = w^t + (1/K) sum_k Delta_k (averaging of w_k)."""
    K = jax.tree.leaves(deltas)[0].shape[0]
    upd = _weighted_sum(deltas, jnp.full((K,), 1.0 / K))
    return jax.tree.map(lambda w, u: (w.astype(jnp.float32) + u).astype(w.dtype),
                        w_t, upd)


def signed_aggregate(w_t, deltas, grads, global_grad, mask=None):
    """Eq. 5: flip the sign of anti-aligned updates (FedNu + sign rule).

    `mask` (optional, scenario drop channel) restricts the rule to the
    uploads that made it: masked signs are zeroed and the 1/K norm
    shrinks to 1/n_arrived; `mask=None` is the exact original rule."""
    inner = _stacked_dot(grads, global_grad)
    K = inner.shape[0]
    if mask is None:
        weights = jnp.sign(inner) / K
    else:
        m = mask.astype(jnp.float32)
        weights = jnp.sign(inner) * m / jnp.maximum(jnp.sum(m), 1.0)
    upd = _weighted_sum(deltas, weights)
    return jax.tree.map(lambda w, u: (w.astype(jnp.float32) + u).astype(w.dtype),
                        w_t, upd)


def folb_weights_single_set(inner: jnp.ndarray) -> jnp.ndarray:
    """Eq. IV-C weights: w_k = <g_k, g1> / sum_k' |<g_k', g1>|."""
    denom = jnp.sum(jnp.abs(inner))
    return inner / jnp.maximum(denom, 1e-30)


def folb_single_set(w_t, deltas, grads):
    """FOLB with S1 = S2 (Eq. IV-C) — the communication-optimal variant the
    paper evaluates.  Anti-aligned deltas contribute their negative."""
    g1 = mean_of(grads)
    inner = _stacked_dot(grads, g1)
    weights = folb_weights_single_set(inner)
    upd = _weighted_sum(deltas, weights)
    return jax.tree.map(lambda w, u: (w.astype(jnp.float32) + u).astype(w.dtype),
                        w_t, upd)


def folb_two_set(w_t, deltas, grads_s1, grads_s2, mask=None):
    """FOLB (Alg. 2 / Eq. IV-A): weights from S1 inner products, normalized
    by the independent S2 estimate.

    `mask` (optional, scenario drop channel) applies to the S1 *updates*
    only: g1 and the weights exclude failed uploads, while the S2 probe
    gradients are separate lightweight transmissions outside the
    per-update drop draw and keep the full set.  `mask=None` is the
    exact original rule."""
    g1 = mean_of(grads_s1) if mask is None else _masked_mean_of(grads_s1,
                                                                mask)
    g2 = mean_of(grads_s2)
    inner1 = _stacked_dot(grads_s1, g1)
    if mask is not None:
        inner1 = inner1 * mask.astype(jnp.float32)
    denom = jnp.sum(_stacked_dot(grads_s2, g2))
    weights = inner1 / jnp.where(jnp.abs(denom) > 1e-30, denom, 1e-30)
    upd = _weighted_sum(deltas, weights)
    return jax.tree.map(lambda w, u: (w.astype(jnp.float32) + u).astype(w.dtype),
                        w_t, upd)


def folb_het(w_t, deltas, grads, gammas, psi: float):
    """Heterogeneity-aware FOLB (Eq. V-B):
    I_k = <g1, g_k> - psi * gamma_k * ||g1||^2;  w_k = I_k / sum|I_k'|."""
    g1 = mean_of(grads)
    inner = _stacked_dot(grads, g1)
    g1_sq = tree.tree_sqnorm(g1)
    scores = inner - psi * gammas * g1_sq
    denom = jnp.sum(jnp.abs(scores))
    weights = scores / jnp.maximum(denom, 1e-30)
    upd = _weighted_sum(deltas, weights)
    return jax.tree.map(lambda w, u: (w.astype(jnp.float32) + u).astype(w.dtype),
                        w_t, upd)


def staleness_discounts(tau: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """FedBuff-style polynomial staleness discount s(τ) = (1 + τ)^{−α}.

    τ counts server model versions elapsed since the client pulled its
    reference parameters; α = 0 disables the discount exactly (the factor
    is the constant 1.0, bit-for-bit)."""
    return jnp.power(1.0 + tau.astype(jnp.float32), -alpha)


def _masked_mean_of(stacked, mask: jnp.ndarray):
    """Mean over the clients with mask == 1 (arrived before the deadline)."""
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m), 1.0)
    return jax.tree.map(
        lambda x: jnp.tensordot(m, x.astype(jnp.float32), axes=1) / n,
        stacked)


def folb_staleness(w_t, deltas, grads, tau, alpha: float = 0.0,
                   gammas=None, psi: float = 0.0, mask=None):
    """Staleness-discounted heterogeneity-aware FOLB (async engines).

    Extends the Eq. V-B score with the FedBuff discount:
        I_k = (<g_k, g1> − ψ γ_k ||g1||²) · (1 + τ_k)^{−α}
    and normalizes over the arrived set only (`mask`, optional): a client
    that missed the deadline contributes neither to g1 nor to the weights.
    With τ = 0, α = 0, ψ = 0 and full mask this is `folb_single_set`.
    """
    g1 = mean_of(grads) if mask is None else _masked_mean_of(grads, mask)
    inner = _stacked_dot(grads, g1)
    scores = inner
    # branch on gammas only: psi may be a traced scalar (a sweepable
    # hyper-parameter), and psi == 0 subtracts an exact +0.0 — bit-
    # identical to skipping the term (gammas and ||g1||² are nonnegative)
    if gammas is not None:
        scores = scores - psi * gammas * tree.tree_sqnorm(g1)
    scores = scores * staleness_discounts(tau, alpha)
    if mask is not None:
        scores = scores * mask.astype(jnp.float32)
    denom = jnp.sum(jnp.abs(scores))
    weights = scores / jnp.maximum(denom, 1e-30)
    upd = _weighted_sum(deltas, weights)
    return jax.tree.map(lambda w, u: (w.astype(jnp.float32) + u).astype(w.dtype),
                        w_t, upd)


def mean_staleness(w_t, deltas, tau, alpha: float = 0.0, mask=None):
    """Staleness-discounted FedAvg: a discounted mean over arrived clients.

    w^{t+1} = w^t + Σ_k s(τ_k) m_k Δ_k / Σ_k s(τ_k) m_k.
    """
    disc = staleness_discounts(tau, alpha)
    if mask is not None:
        disc = disc * mask.astype(jnp.float32)
    weights = disc / jnp.maximum(jnp.sum(disc), 1e-30)
    upd = _weighted_sum(deltas, weights)
    return jax.tree.map(lambda w, u: (w.astype(jnp.float32) + u).astype(w.dtype),
                        w_t, upd)


def aggregate(rule: str, w_t, deltas, grads=None, grads_s2=None,
              global_grad=None, gammas=None, psi: float = 0.0,
              tau=None, alpha: float = 0.0, mask=None):
    """Dispatch by rule name:
    mean | signed | folb | folb2 | folb_het | folb_stale | mean_stale."""
    if rule == "folb_stale":
        t = tau if tau is not None else jnp.zeros(
            jax.tree.leaves(deltas)[0].shape[0], jnp.float32)
        return folb_staleness(w_t, deltas, grads, t, alpha=alpha,
                              gammas=gammas, psi=psi, mask=mask)
    if rule == "mean_stale":
        t = tau if tau is not None else jnp.zeros(
            jax.tree.leaves(deltas)[0].shape[0], jnp.float32)
        return mean_staleness(w_t, deltas, t, alpha=alpha, mask=mask)
    if rule == "mean":
        return fedavg_aggregate(w_t, deltas)
    if rule == "signed":
        gg = global_grad if global_grad is not None else mean_of(grads)
        return signed_aggregate(w_t, deltas, grads, gg, mask=mask)
    if rule == "folb":
        return folb_single_set(w_t, deltas, grads)
    if rule == "folb2":
        return folb_two_set(w_t, deltas, grads, grads_s2, mask=mask)
    if rule == "folb_het":
        return folb_het(w_t, deltas, grads, gammas, psi)
    raise ValueError(f"unknown aggregation rule {rule!r}")

"""End-to-end driver: federated training of a ~100M-parameter dense LM
with the production round engine (scan-over-clients FOLB), checkpointing,
and a serving sanity check at the end.

Full run (a few hundred rounds, ~100M params — intended for a real host):
  PYTHONPATH=src python examples/train_federated_100m.py --rounds 300

CPU smoke (reduced model, runs in ~2 min):
  PYTHONPATH=src python examples/train_federated_100m.py --smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.configs import get_config, n_params
from repro.fed.distributed import RoundConfig, folb_round
from repro.launch.train import make_round_batches
from repro.models import model as model_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--algo", default="folb")
    ap.add_argument("--ckpt-dir", default="/tmp/fed100m_ckpt")
    args = ap.parse_args()

    cfg = get_config("fed100m")
    rounds, clients, seqs, seq_len = args.rounds, 4, 4, 512
    if args.smoke:
        cfg = cfg.reduced(n_layers=4, d_model=256)
        rounds, seqs, seq_len = 8, 2, 128
    print(f"[e2e] {cfg.name}: {n_params(cfg)/1e6:.1f}M params, "
          f"{rounds} FOLB rounds x {clients} clients")

    rc = RoundConfig(algo=args.algo, n_clients=clients, local_steps=2,
                     lr=0.1, mu=0.01, remat=True)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(lambda p, b: folb_round(cfg, rc, p, b))
    batches = make_round_batches(cfg, clients, seqs, seq_len, rounds, seed=0)

    t0 = time.time()
    first = last = None
    for r, batch in enumerate(batches):
        params, metrics = step(params, batch)
        loss = float(metrics["client_loss"])
        first = first if first is not None else loss
        last = loss
        if r % max(1, rounds // 10) == 0 or r == rounds - 1:
            print(f"[round {r:4d}] loss={loss:.4f} "
                  f"({(time.time()-t0)/(r+1):.1f}s/round)")
    print(f"[e2e] loss {first:.4f} -> {last:.4f}")
    ckpt_io.save_checkpoint(f"{args.ckpt_dir}/step_{rounds}", params,
                            step=rounds, extra={"arch": cfg.name})

    # serve the trained model
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits, cache = model_lib.prefill(cfg, params, {"tokens": toks},
                                      cache_len=48)
    out = [jnp.argmax(logits, -1)[:, None].astype(jnp.int32)]
    for _ in range(7):
        logits, cache = model_lib.decode_step(cfg, params, cache, out[-1])
        out.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    print("[e2e] greedy continuation:", jnp.concatenate(out, 1)[0].tolist())
    assert last < first, "training did not reduce loss"
    print("[e2e] OK")


if __name__ == "__main__":
    main()

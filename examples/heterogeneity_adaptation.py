"""Scenario: device heterogeneity (paper Sec. V / Fig. 11).

Devices differ wildly in compute (1..20 local steps per round).  Vanilla
FOLB weights updates only by gradient alignment; heterogeneity-aware FOLB
additionally discounts devices that could barely optimize (γ_k), with the
single line-searched hyper-parameter ψ (Sec. V-B).  This example runs the
ψ line search the paper describes and compares stability.

  PYTHONPATH=src python examples/heterogeneity_adaptation.py
"""
import numpy as np

from repro import fed as fed_api
from repro.configs.paper_models import MCLR
from repro.core.tuning import PSI_GRID, line_search
from repro.data.federated import stack_devices
from repro.data.synthetic import synthetic_alpha_beta
from repro.fed.simulator import FLConfig

ROUNDS = 40


def stability(hist):
    accs = np.asarray(hist["test_acc"][5:])
    return float(np.maximum(0, accs[:-1] - accs[1:]).max())


def main() -> None:
    devs = synthetic_alpha_beta(0, n_devices=30, alpha=1.0, beta=1.0,
                                mean_size=100)
    fed = stack_devices(devs, seed=0)

    base = FLConfig(algo="folb", n_selected=10, mu=1.0, lr=0.05, seed=0)
    h0 = fed_api.run(MCLR, fed, base, ROUNDS, eval_every=1)
    print(f"vanilla FOLB : final acc {h0['test_acc'][-1]:.3f}, "
          f"worst round-to-round drop {stability(h0):.3f}")

    def run_psi(psi: float) -> float:
        fl = FLConfig(algo="folb_het", n_selected=10, mu=1.0, lr=0.05,
                      psi=psi, seed=0)
        h = fed_api.run(MCLR, fed, fl, ROUNDS, eval_every=1)
        # figure of merit: accuracy minus instability penalty
        return h["test_acc"][-1] - stability(h)

    best_psi, scores = line_search(run_psi, PSI_GRID)
    print("psi line search (Sec. V-B):")
    for psi, s in scores.items():
        print(f"  psi={psi:<6g} acc-minus-drop={s:.3f}")

    fl = FLConfig(algo="folb_het", n_selected=10, mu=1.0, lr=0.05,
                  psi=best_psi, seed=0)
    h1 = fed_api.run(MCLR, fed, fl, ROUNDS, eval_every=1)
    print(f"FOLB-het ψ={best_psi:g}: final acc {h1['test_acc'][-1]:.3f}, "
          f"worst drop {stability(h1):.3f}")
    print("\nheterogeneity-aware aggregation trades a slightly different "
          "weighting for\nvisibly fewer accuracy collapses (paper Fig. 11).")


if __name__ == "__main__":
    main()

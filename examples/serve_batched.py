"""Scenario: batched serving of an assigned architecture at reduced scale —
prefill a batch of prompts, then decode with the ring-buffer KV cache
(sliding-window archs) or recurrent state (SSM/xLSTM archs).

  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
  PYTHONPATH=src python examples/serve_batched.py --arch xlstm-1.3b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as model_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    assert cfg.supports_decode, f"{cfg.name} is encoder-only"
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.frontend_positions > 0:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_positions, cfg.d_model))

    prefill = jax.jit(lambda p, b: model_lib.prefill(
        cfg, p, b, cache_len=S + args.gen))
    decode = jax.jit(lambda p, c, t: model_lib.decode_step(cfg, p, c, t))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"[{cfg.name}] prefill {B}x{S}: {time.time()-t0:.2f}s "
          f"(cache: {sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))/2**20:.1f} MiB)")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    seqs = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        seqs.append(tok)
    jax.block_until_ready(logits)
    dt = time.time() - t0
    print(f"[{cfg.name}] decoded {args.gen} x {B} tokens in {dt:.2f}s "
          f"({args.gen * B / max(dt, 1e-9):.1f} tok/s on CPU)")
    out = jnp.concatenate(seqs, axis=1)
    print(f"  sample: {out[0].tolist()}")


if __name__ == "__main__":
    main()

"""Plan-reuse hyper-parameter sweep demo: an lr × α grid on deadline-FOLB
in ONE compiled run.

  PYTHONPATH=src python examples/sweep.py

The sweep engine builds the fleet event timeline once (the same seeded
30-device straggler fleet the BENCH_fed.json tta sweep uses) and runs the
learning math for every (lr, staleness_alpha) grid point inside a single
vmapped ``lax.scan`` — per-config host cost ~zero, compile cost amortized
across the grid, and each member bit-for-bit identical to a solo
``run_async_compiled`` of that config (tests/test_sweep_engine.py).

The table shows what the paper's Sec. V tuning loop actually looks at:
final accuracy and simulated seconds-to-target per grid point — here the
whole grid costs roughly one solo run of host time.

Eval is sweep-native too: every (round, member) metric row comes out of
two batched ``eval_traj`` dispatches (train + test) instead of
S × n_eval × 2 separate ``eval_global`` calls, and the ``eval`` phase
line below shows what that costs on the host.
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.time_to_accuracy import SEED, TARGET_ACC, setup_sweep

ROUNDS = 40
LR_AXIS = (0.02, 0.05, 0.08)
ALPHA_AXIS = (0.0, 0.5, 1.0)


def main():
    from repro import fed as fed_api
    from repro.fed.async_engine import AsyncFLConfig
    from repro.fed.simulator import seconds_to_accuracy
    from repro.fed.sweep_engine import SweepSpec
    from repro.sysmodel import fleet_summary
    from repro.telemetry import PhaseProfiler

    model_cfg, fed, fleet, deadline = setup_sweep()
    print(fleet_summary(fleet))
    print(f"deadline (p90 expected round latency): {deadline:.3f}s")

    base = AsyncFLConfig(mode="deadline", algo="folb", n_selected=10,
                         mu=1.0, deadline=deadline, seed=SEED)
    spec = SweepSpec.from_grid(base, lr=LR_AXIS,
                               staleness_alpha=ALPHA_AXIS)
    print(f"\nsweeping {spec.n_configs} configs "
          f"(lr x staleness_alpha) over ONE shared event plan, "
          f"{ROUNDS} rounds each")

    prof = PhaseProfiler()
    t0 = time.time()
    sweep = fed_api.run(model_cfg, fed, spec, ROUNDS, fleet=fleet,
                        profiler=prof)
    sweep_s = time.time() - t0
    phases = prof.finish()["phases"]

    # one solo compiled run for the host-time comparison (it rebuilds the
    # plan and pays its own dispatch — the cost every extra grid point
    # would add without the sweep engine)
    t0 = time.time()
    fed_api.run(model_cfg, fed, spec.member(0), ROUNDS, fleet=fleet)
    solo_s = time.time() - t0

    print(f"\n{'lr':>6} {'alpha':>6} {'final acc':>10} "
          f"{'secs->' + str(TARGET_ACC):>10}")
    for i, res in enumerate(sweep):
        o = spec.overrides[i]
        secs = seconds_to_accuracy(res, TARGET_ACC)
        secs_str = f"{secs:10.2f}" if secs >= 0 else f"{'—':>10}"
        print(f"{o['lr']:>6.3f} {o['staleness_alpha']:>6.2f} "
              f"{res['test_acc'][-1]:>10.3f} {secs_str}")

    per_cfg = sweep_s / spec.n_configs
    print(f"\nhost time: sweep of {spec.n_configs} configs {sweep_s:.2f}s "
          f"({per_cfg:.2f}s/config) vs one solo compiled run "
          f"{solo_s:.2f}s — per-config cost "
          f"{solo_s / per_cfg:.1f}x lower in the sweep")

    n_eval = len(sweep[0]["round"])
    n_naive = spec.n_configs * n_eval * 2
    print(f"eval phase: {phases.get('eval', 0.0) * 1e3:.1f}ms host time "
          f"for all {spec.n_configs * n_eval} (round, member) metric rows "
          f"— 2 batched eval_traj dispatches instead of "
          f"{n_naive} separate eval_global calls")


if __name__ == "__main__":
    main()

"""Scenario: the full algorithm family side-by-side (paper Figs. 2/7/8 +
the beyond-paper server-optimizer composition).

Runs all eight paper algorithms plus FOLB+server-momentum on Synthetic(1,1)
and prints a one-screen comparison: rounds-to-target, final accuracy,
final loss, stability, and the per-round communication cost class.

  PYTHONPATH=src python examples/algorithm_ablation.py
"""
import dataclasses

import numpy as np

from repro import fed as fed_api
from repro.configs.paper_models import MCLR
from repro.data.federated import stack_devices
from repro.data.synthetic import synthetic_alpha_beta
from repro.fed.simulator import FLConfig, rounds_to_accuracy

ROUNDS, TARGET = 50, 0.70

# (label, config, communication cost per round)
RUNS = [
    ("fedavg", FLConfig(algo="fedavg", mu=0.0), "K params"),
    ("fedprox", FLConfig(algo="fedprox"), "K params"),
    ("fednu_norm", FLConfig(algo="fednu_norm"), "N scalars + K params"),
    ("fednu_direct", FLConfig(algo="fednu_direct"), "N grads + K params"),
    ("fednu_signed", FLConfig(algo="fednu_signed"), "N grads + K params"),
    ("folb", FLConfig(algo="folb"), "K params + K grads"),
    ("folb2", FLConfig(algo="folb2"), "2K (Alg. 2 two-set)"),
    ("folb_het", FLConfig(algo="folb_het", psi=1.0), "K params+grads+gammas"),
    ("folb+momentum",
     FLConfig(algo="folb", server_opt="momentum"), "K params + K grads"),
]


def main() -> None:
    fed = stack_devices(
        synthetic_alpha_beta(0, 30, 1.0, 1.0, mean_size=120), seed=0)
    print(f"Synthetic(1,1), N=30 devices, K=10/round, {ROUNDS} rounds, "
          f"target {TARGET:.0%}\n")
    print(f"{'algorithm':15s} {'r2a':>5s} {'acc':>6s} {'loss':>7s} "
          f"{'drop':>6s}  comm/round")
    for label, fl, comm in RUNS:
        fl = dataclasses.replace(fl, n_selected=10, lr=0.05, seed=0)
        h = fed_api.run(MCLR, fed, fl, ROUNDS, eval_every=2)
        accs = np.asarray(h["test_acc"])
        r2a = rounds_to_accuracy(h, TARGET)
        drop = float(np.maximum(0, accs[:-1] - accs[1:]).max())
        print(f"{label:15s} {r2a if r2a >= 0 else '-':>5} {accs[-1]:6.3f} "
              f"{h['train_loss'][-1]:7.3f} {drop:6.2f}  {comm}")
    print("\nLB-near-optimal selection (fednu_direct) converges fastest but "
          "probes all N\ndevices; FOLB gets the best final model at FedAvg's "
          "communication cost;\nserver momentum (beyond-paper) smooths the "
          "FOLB trajectory.")


if __name__ == "__main__":
    main()

"""Async heterogeneity demo: the same FOLB workload on all three
scheduling policies, compared on simulated wall-clock time-to-accuracy.

  PYTHONPATH=src python examples/async_heterogeneity.py

Reuses the exact sweep setting of ``benchmarks/time_to_accuracy.py`` (the
BENCH_fed.json artifact tracked across PRs): a seeded fleet of 30 devices
with log-normal compute/bandwidth and a 30% straggler tail (25x slowdown)
trains MCLR on non-IID Synthetic(1,1) under

  sync      — the paper's round barrier: every round waits for the
              slowest selected straggler
  deadline  — rounds cut at the p90 expected latency; stragglers land in
              later rounds as staleness-discounted late updates
  fedbuff   — no rounds at all: devices always in flight, aggregate
              every few arrivals with (1+τ)^-α discounts

Watch the seconds column: the learning math is identical FOLB throughout
— the only thing that changes is *when* updates are allowed to arrive,
which is exactly the axis the paper's Sec. V optimizes.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.time_to_accuracy import (TARGET_ACC, setup_sweep,
                                         time_to_accuracy_results)
from repro.sysmodel import fleet_summary

ROUNDS = 60


def main():
    _, _, fleet, deadline = setup_sweep()
    print(fleet_summary(fleet))
    print(f"deadline (p90 expected round latency): {deadline:.3f}s\n")

    results = time_to_accuracy_results(ROUNDS)
    print(f"{'run':>15} {'rounds->' + str(TARGET_ACC):>11} "
          f"{'secs->' + str(TARGET_ACC):>10} {'final acc':>10} "
          f"{'total wall':>11}")
    for r in results:
        print(f"{r['name']:>15} {r['rounds_to_acc']:>11d} "
              f"{r['secs_to_acc']:>10.2f} {r['final_acc']:>10.3f} "
              f"{r['final_wall_clock']:>10.1f}s")


if __name__ == "__main__":
    main()

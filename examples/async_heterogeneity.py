"""Async heterogeneity demo: the same FOLB workload on all three
scheduling policies, compared on simulated wall-clock time-to-accuracy.

  PYTHONPATH=src python examples/async_heterogeneity.py

Reuses the exact sweep setting of ``benchmarks/time_to_accuracy.py`` (the
BENCH_fed.json artifact tracked across PRs): a seeded fleet of 30 devices
with log-normal compute/bandwidth and a 30% straggler tail (25x slowdown)
trains MCLR on non-IID Synthetic(1,1) under

  sync      — the paper's round barrier: every round waits for the
              slowest selected straggler
  deadline  — rounds cut at the p90 expected latency; stragglers land in
              later rounds as staleness-discounted late updates
  fedbuff   — no rounds at all: devices always in flight, aggregate
              every few arrivals with (1+τ)^-α discounts

Watch the seconds column: the learning math is identical FOLB throughout
— the only thing that changes is *when* updates are allowed to arrive,
which is exactly the axis the paper's Sec. V optimizes.

``--compiled`` additionally runs the async sweep configs through the
virtual-event scan engine (``run_async_compiled``): the same event
timeline compiled into one XLA program, bit-for-bit identical histories,
with the python-loop vs scan host-time comparison printed per mode.
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.time_to_accuracy import (SEED, TARGET_ACC, setup_sweep,
                                         time_to_accuracy_results)
from repro.sysmodel import fleet_summary

ROUNDS = 60


def compiled_comparison(rounds: int = ROUNDS) -> None:
    """Run deadline + fedbuff through both async engines and print the
    host-time comparison (the simulated history is identical by
    construction — asserted below)."""
    from repro.fed.async_engine import AsyncFLConfig, run_async
    from repro.fed.scan_engine import run_async_compiled
    model_cfg, fed, fleet, deadline = setup_sweep()
    configs = {
        "folb/deadline": AsyncFLConfig(
            mode="deadline", algo="folb", n_selected=10, mu=1.0, lr=0.05,
            deadline=deadline, staleness_alpha=0.5, seed=SEED),
        "folb/fedbuff": AsyncFLConfig(
            mode="fedbuff", algo="folb", mu=1.0, lr=0.05, buffer_size=5,
            concurrency=10, staleness_alpha=0.5, seed=SEED),
    }
    print(f"\n{'run':>15} {'loop host-s':>12} {'scan host-s':>12} "
          f"{'speedup':>8} {'bit-for-bit':>12}")
    for name, afl in configs.items():
        run_async(model_cfg, fed, afl, fleet, rounds=rounds)   # warm jits
        t0 = time.time()
        h_loop = run_async(model_cfg, fed, afl, fleet, rounds=rounds)
        loop_s = time.time() - t0
        run_async_compiled(model_cfg, fed, afl, fleet, rounds=rounds)
        t0 = time.time()
        h_scan = run_async_compiled(model_cfg, fed, afl, fleet,
                                    rounds=rounds)
        scan_s = time.time() - t0
        same = (h_loop["test_acc"] == h_scan["test_acc"]
                and h_loop["wall_clock"] == h_scan["wall_clock"]
                and h_loop["stale_mean"] == h_scan["stale_mean"])
        print(f"{name:>15} {loop_s:>12.2f} {scan_s:>12.2f} "
              f"{loop_s / scan_s:>7.2f}x {'yes' if same else 'NO':>12}")
        assert same, f"{name}: compiled history diverged from the loop"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compiled", action="store_true",
                    help="also run the virtual-event scan engine and "
                         "print the loop-vs-scan host-time comparison")
    args = ap.parse_args()

    _, _, fleet, deadline = setup_sweep()
    print(fleet_summary(fleet))
    print(f"deadline (p90 expected round latency): {deadline:.3f}s\n")

    results = time_to_accuracy_results(ROUNDS)
    print(f"{'run':>15} {'rounds->' + str(TARGET_ACC):>11} "
          f"{'secs->' + str(TARGET_ACC):>10} {'final acc':>10} "
          f"{'total wall':>11}")
    for r in results:
        print(f"{r['name']:>15} {r['rounds_to_acc']:>11d} "
              f"{r['secs_to_acc']:>10.2f} {r['final_acc']:>10.3f} "
              f"{r['final_wall_clock']:>10.1f}s")
    if args.compiled:
        compiled_comparison()


if __name__ == "__main__":
    main()

"""Async heterogeneity demo: the same FOLB workload on all three
scheduling policies, compared on simulated wall-clock time-to-accuracy.

  PYTHONPATH=src python examples/async_heterogeneity.py

Reuses the exact sweep setting of ``benchmarks/time_to_accuracy.py`` (the
BENCH_fed.json artifact tracked across PRs): a seeded fleet of 30 devices
with log-normal compute/bandwidth and a 30% straggler tail (25x slowdown)
trains MCLR on non-IID Synthetic(1,1) under

  sync      — the paper's round barrier: every round waits for the
              slowest selected straggler
  deadline  — rounds cut at the p90 expected latency; stragglers land in
              later rounds as staleness-discounted late updates
  fedbuff   — no rounds at all: devices always in flight, aggregate
              every few arrivals with (1+τ)^-α discounts

Watch the seconds column: the learning math is identical FOLB throughout
— the only thing that changes is *when* updates are allowed to arrive,
which is exactly the axis the paper's Sec. V optimizes.

``--compiled`` additionally runs the async sweep configs through the
virtual-event scan engine (``run_async_compiled``): the same event
timeline compiled into one XLA program, bit-for-bit identical histories,
with the python-loop vs scan host-time comparison printed per mode.

``--corrupt`` injects payload corruption (NaN + 100x norm inflation on
5% of uploads) into the deadline run and prints the accuracy damage;
adding ``--guard`` also runs the same corrupted timeline through the
in-kernel update-validation guard, showing the rescue side by side with
the guard's rejection counters.

``--telemetry`` turns on the observability layer for the deadline run
and prints the per-round metric summary (FOLB scores, staleness
histogram, modeled network bytes, straggler pool) plus the host-phase
profile; ``--trace PATH`` additionally exports the run's virtual
timeline as Chrome trace-event JSON for ui.perfetto.dev.
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.time_to_accuracy import (SEED, TARGET_ACC, setup_sweep,
                                         time_to_accuracy_results)
from repro.sysmodel import fleet_summary

ROUNDS = 60


def compiled_comparison(rounds: int = ROUNDS) -> None:
    """Run deadline + fedbuff through both async engines and print the
    host-time comparison (the simulated history is identical by
    construction — asserted below)."""
    from repro import fed as fed_api
    from repro.fed.async_engine import AsyncFLConfig
    model_cfg, fed, fleet, deadline = setup_sweep()
    configs = {
        "folb/deadline": AsyncFLConfig(
            mode="deadline", algo="folb", n_selected=10, mu=1.0, lr=0.05,
            deadline=deadline, staleness_alpha=0.5, seed=SEED),
        "folb/fedbuff": AsyncFLConfig(
            mode="fedbuff", algo="folb", mu=1.0, lr=0.05, buffer_size=5,
            concurrency=10, staleness_alpha=0.5, seed=SEED),
    }
    print(f"\n{'run':>15} {'loop host-s':>12} {'scan host-s':>12} "
          f"{'speedup':>8} {'bit-for-bit':>12}")
    for name, afl in configs.items():
        fed_api.run(model_cfg, fed, afl, rounds, fleet=fleet,
                    engine="loop")                             # warm jits
        t0 = time.time()
        h_loop = fed_api.run(model_cfg, fed, afl, rounds, fleet=fleet,
                             engine="loop")
        loop_s = time.time() - t0
        fed_api.run(model_cfg, fed, afl, rounds, fleet=fleet,
                    engine="scan")
        t0 = time.time()
        h_scan = fed_api.run(model_cfg, fed, afl, rounds, fleet=fleet,
                             engine="scan")
        scan_s = time.time() - t0
        same = (h_loop["test_acc"] == h_scan["test_acc"]
                and h_loop["wall_clock"] == h_scan["wall_clock"]
                and h_loop["stale_mean"] == h_scan["stale_mean"])
        print(f"{name:>15} {loop_s:>12.2f} {scan_s:>12.2f} "
              f"{loop_s / scan_s:>7.2f}x {'yes' if same else 'NO':>12}")
        assert same, f"{name}: compiled history diverged from the loop"


def corruption_demo(rounds: int = ROUNDS, guard: bool = False) -> None:
    """Deadline-FOLB on one corrupted timeline (5% of payloads NaN'd or
    norm-inflated 100x), unguarded — and, with ``guard``, rescued by the
    in-kernel update-validation layer on the same realized corruption."""
    import numpy as np

    from repro import fed as fed_api
    from repro.fed.async_engine import AsyncFLConfig
    from repro.kernels import GuardConfig
    from repro.sysmodel import ScenarioConfig

    model_cfg, fed, fleet, deadline = setup_sweep()
    sc = ScenarioConfig(nan_prob=0.025, scale_prob=0.025, scale_mag=100.0,
                        seed=SEED)
    variants = [("clean", None, None), ("corrupt", sc, None)]
    if guard:
        variants.append(("corrupt+guard", sc,
                         GuardConfig(nonfinite=True, clip_mult=5.0,
                                     gate_mult=20.0)))
    print(f"\ncorruption (deadline-FOLB, {rounds} rounds, 5% payloads "
          f"NaN/100x-inflated):")
    print(f"{'run':>15} {'final acc':>10} {'best acc':>9} "
          f"{'n_nonfinite':>12} {'n_gated':>8} {'n_clipped':>10}")
    for name, scenario, g in variants:
        afl = AsyncFLConfig(mode="deadline", algo="folb", n_selected=10,
                            mu=1.0, lr=0.05, deadline=deadline,
                            staleness_alpha=0.5, seed=SEED,
                            telemetry=True, guard=g)
        res = fed_api.run(model_cfg, fed, afl, rounds, fleet=fleet,
                          scenario=scenario)
        acc = np.asarray(res["test_acc"], np.float64)
        m = res.metrics
        print(f"{name:>15} {acc[-1]:>10.3f} {acc.max():>9.3f} "
              f"{np.sum(m['n_nonfinite']):>12.0f} "
              f"{np.sum(m['n_gated']):>8.0f} "
              f"{np.sum(m['n_clipped']):>10.0f}")
    if not guard:
        print("  (rerun with --guard to see the in-kernel rescue)")


def telemetry_demo(rounds: int = ROUNDS, trace_path: str = None) -> None:
    """Deadline-FOLB with the observability layer on: per-round metric
    summary, straggler/network accounting, host-phase profile, and
    (optionally) the Perfetto trace of the virtual timeline."""
    import jax
    import numpy as np

    from repro import fed as fed_api
    from repro.fed.async_engine import (AsyncFLConfig, build_plan,
                                        deadline_selection_probs)
    from repro.models import small
    from repro.sysmodel import round_cost_for
    from repro.telemetry import write_trace
    from repro.telemetry.trace import deadline_trace_events

    model_cfg, fed, fleet, deadline = setup_sweep()
    afl = AsyncFLConfig(mode="deadline", algo="folb", n_selected=10,
                        mu=1.0, lr=0.05, deadline=deadline,
                        staleness_alpha=0.5, seed=SEED, telemetry=True)
    sizes = np.asarray(fed.mask.sum(1))
    cost = round_cost_for(model_cfg, small.init_small(
        model_cfg, jax.random.PRNGKey(SEED)), uploads_gradient=True)
    sel_probs = deadline_selection_probs(afl, fleet, cost, sizes)
    plan = build_plan(afl, fleet, cost, sizes, rounds,
                      jax.random.PRNGKey(SEED), sel_probs)
    res = fed_api.run(model_cfg, fed, afl, rounds, fleet=fleet, plan=plan)

    m = res.metrics
    print(f"\ntelemetry (deadline-FOLB, {rounds} rounds):")
    print(f"{'round':>6} {'score_mean':>11} {'w_entropy':>10} "
          f"{'upd_norm':>9} {'n_contrib':>9} {'n_cut':>6} {'pool':>5} "
          f"{'MB up':>7}")
    for t in range(0, rounds, max(rounds // 8, 1)):
        print(f"{t:>6} {m['score_mean'][t]:>11.4f} "
              f"{m['weight_entropy'][t]:>10.3f} "
              f"{m['update_norm'][t]:>9.4f} {m['n_contrib'][t]:>9.0f} "
              f"{m['n_cut'][t]:>6.0f} {m['pool_live'][t]:>5.0f} "
              f"{m['bytes_up'][t] / 1e6:>7.3f}")
    print(f"  totals: {m['bytes_up'].sum() / 1e6:.1f} MB up, "
          f"{m['bytes_down'].sum() / 1e6:.1f} MB down; "
          f"selection entropy {m['selection_entropy']:.3f} nats")
    print("  host phases: " + ", ".join(
        f"{k}={v:.3f}s" for k, v in res.profile["phases"].items())
        + f" (coverage {res.profile['coverage']:.2f})")
    if trace_path:
        events = deadline_trace_events(plan, fleet=fleet, cost=cost,
                                       sizes=sizes)
        print(f"  trace: {write_trace(trace_path, events)} "
              f"({len(events)} events; load in ui.perfetto.dev)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compiled", action="store_true",
                    help="also run the virtual-event scan engine and "
                         "print the loop-vs-scan host-time comparison")
    ap.add_argument("--corrupt", action="store_true",
                    help="inject payload corruption into the deadline run "
                         "and print the accuracy damage")
    ap.add_argument("--guard", action="store_true",
                    help="with --corrupt: also run the corrupted timeline "
                         "through the in-kernel update-validation guard")
    ap.add_argument("--telemetry", action="store_true",
                    help="run the deadline config with the observability "
                         "layer on and print metric/profile summaries")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --telemetry: export the virtual timeline "
                         "as Chrome trace-event JSON to PATH")
    args = ap.parse_args()

    _, _, fleet, deadline = setup_sweep()
    print(fleet_summary(fleet))
    print(f"deadline (p90 expected round latency): {deadline:.3f}s\n")

    results = time_to_accuracy_results(ROUNDS)
    print(f"{'run':>15} {'rounds->' + str(TARGET_ACC):>11} "
          f"{'secs->' + str(TARGET_ACC):>10} {'final acc':>10} "
          f"{'total wall':>11}")
    for r in results:
        print(f"{r['name']:>15} {r['rounds_to_acc']:>11d} "
              f"{r['secs_to_acc']:>10.2f} {r['final_acc']:>10.3f} "
              f"{r['final_wall_clock']:>10.1f}s")
    if args.compiled:
        compiled_comparison()
    if args.corrupt or args.guard:
        corruption_demo(guard=args.guard)
    if args.telemetry or args.trace:
        telemetry_demo(trace_path=args.trace)


if __name__ == "__main__":
    main()

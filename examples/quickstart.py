"""Quickstart: reproduce the paper's core result in ~a minute on CPU.

Runs FedAvg, FedProx and FOLB on the paper's Synthetic(1,1) heterogeneous
dataset (multinomial logistic regression, 30 devices, K=10 per round) and
prints the convergence comparison — the Fig. 7/8 + Table I story.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro import fed as fed_api
from repro.configs.paper_models import MCLR
from repro.data.federated import stack_devices
from repro.data.synthetic import synthetic_alpha_beta
from repro.fed.simulator import FLConfig, rounds_to_accuracy

ROUNDS = 60
TARGET = 0.70


def main() -> None:
    devices = synthetic_alpha_beta(seed=0, n_devices=30, alpha=1.0, beta=1.0,
                                   mean_size=120)
    fed = stack_devices(devices, seed=0)
    print(f"Synthetic(1,1): {fed.n_devices} devices, "
          f"{int(fed.mask.sum())} train samples, non-IID power-law split\n")

    results = {}
    for algo, mu in (("fedavg", 0.0), ("fedprox", 1.0), ("folb", 1.0),
                     ("fednu_direct", 1.0)):
        fl = FLConfig(algo=algo, n_selected=10, mu=mu, lr=0.05, seed=0)
        hist = fed_api.run(MCLR, fed, fl, ROUNDS, eval_every=2)
        results[algo] = hist
        r2a = rounds_to_accuracy(hist, TARGET)
        print(f"{algo:8s}  loss {hist['train_loss'][0]:.3f} -> "
              f"{hist['train_loss'][-1]:.3f}   acc {hist['test_acc'][-1]:.3f}"
              f"   rounds-to-{TARGET:.0%}: {r2a if r2a >= 0 else '>'+str(ROUNDS)}")

    print("\nround-by-round test accuracy:")
    print("round  " + "  ".join(f"{a:>8s}" for a in results))
    for i, r in enumerate(results["folb"]["round"]):
        row = "  ".join(f"{results[a]['test_acc'][i]:8.3f}" for a in results)
        print(f"{r:5d}  {row}")

    nu = rounds_to_accuracy(results["fednu_direct"], TARGET)
    base = min(rounds_to_accuracy(results["fedavg"], TARGET) % (ROUNDS + 1),
               rounds_to_accuracy(results["fedprox"], TARGET) % (ROUNDS + 1))
    print(f"\nLB-near-optimal selection reached {TARGET:.0%} in {nu} rounds "
          f"vs best uniform baseline {base}\n(the paper's fast-convergence "
          f"claim); FOLB matches final accuracy at the\nsame communication "
          f"cost as FedAvg.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
